"""TierManager: budgets, LRU demotion, pinning, heat promotion, async
staging, and the working-set-exceeds-memory KMeans acceptance scenario."""
import numpy as np
import pytest

from repro.core import (CapacityError, DataUnit, PilotComputeDescription,
                        PilotComputeService, TierManager, kmeans, make_backend,
                        make_blobs)

KB = 1024


def _tm(tmp_path, device_budget=None, host_budget=None, promote_threshold=0):
    backends = {"file": make_backend("file", root=tmp_path / "file"),
                "host": make_backend("host"),
                "device": make_backend("device")}
    return TierManager(backends,
                       {"device": device_budget, "host": host_budget},
                       promote_threshold=promote_threshold)


def _arr(i, kb=1):
    return np.full((kb * KB // 4,), i, dtype=np.float32)


def test_device_budget_never_exceeded(tmp_path):
    tm = _tm(tmp_path, device_budget=4 * KB)
    for i in range(8):
        tm.put(f"p{i}", _arr(i), "device")
        assert tm.usage("device") <= 4 * KB
    assert tm.peak_usage("device") <= 4 * KB
    # nothing was dropped: every partition readable, contents intact
    for i in range(8):
        np.testing.assert_array_equal(tm.get(f"p{i}"), _arr(i))
    # the overflow went one tier colder, not to the floor
    assert len(tm.resident_keys("device")) == 4
    assert len(tm.resident_keys("host")) == 4


def test_lru_demotion_order(tmp_path):
    tm = _tm(tmp_path, device_budget=3 * KB)
    for k in ("a", "b", "c"):
        tm.put(k, _arr(0), "device")
    tm.get("a")                      # a is now hotter than b
    tm.put("d", _arr(0), "device")   # needs room: LRU victim must be b
    assert tm.tier_of("b") == "host"
    for k in ("a", "c", "d"):
        assert tm.tier_of(k) == "device"


def test_pin_survives_eviction_pressure(tmp_path):
    tm = _tm(tmp_path, device_budget=2 * KB)
    tm.put("pinned", _arr(7), "device", pinned=True)
    for i in range(4):
        tm.put(f"x{i}", _arr(i), "device")
    assert tm.tier_of("pinned") == "device"
    np.testing.assert_array_equal(tm.get("pinned"), _arr(7))
    # when only pinned data remains and the newcomer cannot fit: explicit error
    with pytest.raises(CapacityError):
        tm.put("big", _arr(0, kb=2), "device")
    tm.unpin("pinned")
    tm.put("big", _arr(0, kb=2), "device")       # now evictable
    assert tm.tier_of("pinned") == "host"


def test_put_replacement_capacity_error_keeps_old_copy(tmp_path):
    """A refused re-placement must leave the pre-existing copy resident."""
    tm = _tm(tmp_path, device_budget=1 * KB)
    tm.put("k", _arr(1), "host")
    with pytest.raises(CapacityError):
        tm.put("k", _arr(2, kb=2), "device")
    assert tm.tier_of("k") == "host"
    np.testing.assert_array_equal(tm.get("k"), _arr(1))


def test_put_same_tier_overflow_keeps_accounting(tmp_path):
    """A refused same-tier overwrite must not understate tier usage."""
    tm = _tm(tmp_path, host_budget=1 * KB)
    tm.put("a", _arr(1), "host")
    with pytest.raises(CapacityError):
        tm.put("a", _arr(2, kb=2), "host")
    assert tm.usage("host") == 1 * KB
    assert tm.tier_of("a") == "host"
    tm.put("b", _arr(3), "host")          # budget still enforced: 'a' demotes
    assert tm.usage("host") <= 1 * KB
    assert tm.tier_of("a") == "file"
    np.testing.assert_array_equal(tm.get("a"), _arr(1))


def test_oversized_value_raises(tmp_path):
    tm = _tm(tmp_path, device_budget=1 * KB)
    with pytest.raises(CapacityError):
        tm.put("big", _arr(0, kb=2), "device")


def test_promote_demote_roundtrip_preserves_contents(tmp_path):
    tm = _tm(tmp_path)
    val = np.random.default_rng(0).normal(size=(257, 3)).astype(np.float32)
    tm.put("x", val, "file")
    for tier in ("host", "device", "host", "file", "device", "file"):
        assert tm.stage("x", tier) == tier
        assert tm.tier_of("x") == tier
        np.testing.assert_array_equal(tm.get("x"), val)


def test_async_stage_future_resolves(tmp_path):
    tm = _tm(tmp_path)
    tm.put("x", _arr(3), "file")
    fut = tm.stage_async("x", "device")
    assert fut.result(timeout=10) == "device"
    assert tm.tier_of("x") == "device"
    np.testing.assert_array_equal(tm.get("x"), _arr(3))
    # a capacity-refused stage resolves (to the unchanged tier), not raises
    tm2 = _tm(tmp_path / "b", device_budget=1 * KB)
    tm2.put("big", _arr(0, kb=2), "host")
    assert tm2.stage_async("big", "device").result(timeout=10) == "host"


def test_heat_promotes_hot_partition_file_to_device(tmp_path):
    tm = _tm(tmp_path, promote_threshold=2)
    tm.put("hot", _arr(5), "file")
    for _ in range(4):
        tm.get("hot")
        tm.drain(timeout=10)
    assert tm.tier_of("hot") == "device"     # file -> host -> device
    np.testing.assert_array_equal(tm.get("hot"), _arr(5))


def test_dataunit_pin_and_residency(tmp_path):
    tm = _tm(tmp_path, device_budget=4 * KB)
    parts = [_arr(i) for i in range(4)]
    du = DataUnit.from_partitions("du", parts, tm.backends, tier="device",
                                  tier_manager=tm)
    assert du.resident_fraction("device") == 1.0
    du.pin()
    # pressure from another dataset cannot displace the pinned DU
    for i in range(4):
        with pytest.raises(CapacityError):
            tm.put(f"other{i}", _arr(i), "device")
    assert du.resident_fraction("device") == 1.0
    du.unpin()
    tm.put("other", _arr(0), "device")
    assert du.resident_fraction("device") == 0.75


def test_kmeans_working_set_2x_device_budget(tmp_path):
    """Acceptance: device budget N, KMeans working set 2N — the budget is
    never exceeded, the run completes, and numerics match an unmanaged run."""
    pts, _ = make_blobs(16_000, 8, d=8, seed=2)
    parts = 8
    part_bytes = pts.nbytes // parts
    budget = 4 * part_bytes + part_bytes // 2    # fits half the partitions
    tm = _tm(tmp_path, device_budget=budget, promote_threshold=2)
    du = DataUnit.from_array("pts2x", pts, parts, tm.backends, tier="device",
                             tier_manager=tm)
    res = du.residency()
    assert res.get("device", 0) < parts          # pressure demoted some
    r = kmeans(du, k=8, iters=3, seed=0)
    tm.drain(timeout=30)
    assert tm.peak_usage("device") <= budget
    assert np.isfinite(r.sse_history).all()
    # same numerics as a plain unmanaged host-tier run
    backends = {"host": make_backend("host"), "device": make_backend("device")}
    du_ref = DataUnit.from_array("ref", pts, parts, backends, tier="host")
    r_ref = kmeans(du_ref, k=8, iters=3, seed=0)
    np.testing.assert_allclose(r.sse_history, r_ref.sse_history, rtol=1e-4)


def test_pilot_exposes_retained_memory(tmp_path):
    svc = PilotComputeService()
    try:
        pilot = svc.submit_pilot(PilotComputeDescription(
            backend="inprocess", memory_gb=0.25))
        assert pilot.tier_manager is not None
        assert pilot.retained_memory_bytes == int(0.25 * 2 ** 30)
        assert pilot.tier_manager.budget("device") == int(0.25 * 2 ** 30)
        # DUs created through the pilot's manager land in its device tier
        du = DataUnit.from_array("w", np.ones((64, 4), np.float32), 2,
                                 pilot.tier_manager.backends, tier="device",
                                 tier_manager=pilot.tier_manager)
        assert du.resident_fraction("device") == 1.0
    finally:
        svc.cancel_all()
