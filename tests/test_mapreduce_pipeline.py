"""Pipelined map_reduce engine: fused partial reduction equals the
sequential baseline, depth-k prefetch drives the stager, per-pilot CU
grouping cuts reduce-phase data motion, and BatchPipeline staging shares
the TierManager budget model."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced
from repro.core import (ComputeDataManager, DataUnit,
                        PilotComputeDescription, PilotComputeService,
                        TierManager, make_backend, map_reduce)
from repro.data.pipeline import BatchPipeline, corpus_data_unit


def _tm(tmp_path, device_budget=None, host_budget=None,
        promote_threshold=0):
    backends = {"file": make_backend("file", root=tmp_path / "f"),
                "host": make_backend("host"),
                "device": make_backend("device")}
    return TierManager(backends,
                       {"device": device_budget, "host": host_budget},
                       promote_threshold=promote_threshold)


def _sum_mr(du, **kw):
    return float(map_reduce(du, lambda p: jnp.sum(p), lambda a, b: a + b,
                            **kw))


def test_pipelined_matches_sequential_and_reference(tmp_path):
    arr = np.arange(64 * 8, dtype=np.float32).reshape(64, 8)
    tm = _tm(tmp_path)
    du = DataUnit.from_array("mr", arr, 8, tm.backends, tier="file",
                             tier_manager=tm)
    try:
        ref = float(arr.sum())
        assert _sum_mr(du, pipeline=False) == pytest.approx(ref, rel=1e-5)
        assert _sum_mr(du, prefetch_depth=3) == pytest.approx(ref, rel=1e-5)
        # the depth-k loop staged cold partitions hot through the manager
        tm.drain(timeout=10)
        assert any(e["op"] == "promote" for e in tm.events)
    finally:
        tm.close()


def test_pipelined_device_over_budget_respects_budget(tmp_path):
    arr = np.arange(4096, dtype=np.float32).reshape(512, 8)
    parts = 8
    part_bytes = arr.nbytes // parts
    budget = 4 * part_bytes + part_bytes // 2
    tm = _tm(tmp_path, device_budget=budget)
    du = DataUnit.from_array("dev", arr, parts, tm.backends, tier="device",
                             tier_manager=tm)
    try:
        total = _sum_mr(du, prefetch_depth=2)
        tm.drain(timeout=30)
        assert total == pytest.approx(float(arr.sum()), rel=1e-5)
        assert tm.peak_usage("device") <= budget
    finally:
        tm.close()


def test_manager_path_fuses_one_cu_per_pilot(tmp_path):
    svc = PilotComputeService()
    try:
        svc.submit_pilot(PilotComputeDescription(backend="inprocess"))
        manager = ComputeDataManager(svc)
        backends = {"host": make_backend("host"),
                    "device": make_backend("device")}
        arr = np.ones((256, 4), np.float32)
        du = DataUnit.from_array("grp", arr, 8, backends, tier="host")
        n0 = len(manager.history)
        total = _sum_mr(du, manager=manager)
        assert total == pytest.approx(float(arr.sum()), rel=1e-5)
        # fused partial reduction: one grouped CU per healthy pilot
        assert len(manager.history) - n0 == 1
        total = _sum_mr(du, manager=manager, pipeline=False)
        assert total == pytest.approx(float(arr.sum()), rel=1e-5)
        # the legacy engine still submits one CU per partition
        assert len(manager.history) - n0 == 1 + du.num_partitions
    finally:
        svc.cancel_all()


def test_batch_pipeline_stages_through_shared_tier_budget(tmp_path):
    cfg = reduced(get_config("llama3_2_1b"))
    shard_tokens = 50_000
    host_budget = 3 * shard_tokens // 4 * 4      # < one full shard of int32
    tm = _tm(tmp_path, host_budget=host_budget)
    du = corpus_data_unit("corp", cfg, num_tokens=4 * shard_tokens,
                          backends=tm.backends, num_shards=4,
                          tier_manager=tm)
    pipe = BatchPipeline(du, cfg, batch=2, seq_len=64, stage_depth=2)
    try:
        for _ in range(4):
            b = next(pipe)
            assert b["tokens"].shape == (2, 64)
        tm.drain(timeout=30)
        # training input staging rides the analytics budget model: the host
        # tier never exceeds its byte budget even with prefetch in flight,
        # and over-budget stages are refused, not forced
        assert tm.peak_usage("host") <= host_budget
        assert tm.counters["stage_refused"] > 0
    finally:
        pipe.close()
        tm.close()
        assert not pipe._thread.is_alive()


def test_adaptive_depth_tracks_stage_vs_compute_ratio():
    from repro.core.mapreduce import _AdaptiveDepth

    # no observations yet: the PR 2 default depth applies
    assert _AdaptiveDepth(seed_stage=0.5).depth == 2
    # staging 6x compute (profile-seeded) -> depth 6
    ad = _AdaptiveDepth(seed_stage=0.012)
    for _ in range(4):
        ad.observe(compute_s=0.002, wait_s=0.0)
    assert ad.depth == 6
    # compute-dominated -> one look-ahead suffices
    ad = _AdaptiveDepth(seed_stage=0.0)
    for _ in range(4):
        ad.observe(compute_s=0.01, wait_s=0.0005)
    assert ad.depth == 1
    # observed waits override an optimistic (zero) profile seed
    ad = _AdaptiveDepth(seed_stage=0.0)
    for _ in range(6):
        ad.observe(compute_s=0.001, wait_s=0.004)
    assert ad.depth >= 3
    # clamped to max_depth
    ad = _AdaptiveDepth(seed_stage=10.0)
    ad.observe(compute_s=1e-4)
    assert ad.depth == ad.max_depth


def test_adaptive_default_depth_matches_reference(tmp_path):
    """prefetch_depth=None (the new default) runs the adaptive engine and
    still produces the exact sequential result on a managed cold DU."""
    arr = np.arange(64 * 8, dtype=np.float32).reshape(64, 8)
    tm = _tm(tmp_path)
    du = DataUnit.from_array("ad", arr, 8, tm.backends, tier="file",
                             tier_manager=tm)
    try:
        assert _sum_mr(du) == pytest.approx(float(arr.sum()), rel=1e-5)
        tm.drain(timeout=10)
    finally:
        tm.close()


def test_unmanaged_du_pipeline_is_a_noop_fallback(tmp_path):
    backends = {"host": make_backend("host")}
    arr = np.arange(128, dtype=np.float32)
    du = DataUnit.from_array("plain", arr, 4, backends, tier="host")
    assert du.prefetch_window(0, 3) == []
    assert _sum_mr(du, prefetch_depth=4) == pytest.approx(float(arr.sum()),
                                                          rel=1e-5)


def test_prebind_wait_s_threads_through_map_reduce_submissions(tmp_path):
    """Regression: `prebind_wait_s` was plumbed through `submit` but not
    through map_reduce's internal submissions — every CU description
    map_reduce builds (pipelined groups AND the legacy per-partition
    path) must now carry the caller's override."""
    svc = PilotComputeService()
    try:
        svc.submit_pilot(PilotComputeDescription(backend="inprocess"))
        manager = ComputeDataManager(svc)
        backends = {"host": make_backend("host"),
                    "device": make_backend("device")}
        arr = np.ones((64, 4), np.float32)
        du = DataUnit.from_array("pw", arr, 4, backends, tier="host")

        seen = []
        orig_submit = manager.submit
        orig_submit_tasks = manager.submit_tasks

        def spy_submit(cu_desc, **kw):
            seen.append(cu_desc.prebind_wait_s)
            return orig_submit(cu_desc, **kw)

        def spy_submit_tasks(items, **kw):
            seen.extend(d.prebind_wait_s for d in items)
            return orig_submit_tasks(items, **kw)

        manager.submit = spy_submit
        manager.submit_tasks = spy_submit_tasks

        ref = float(arr.sum())
        total = map_reduce(du, lambda p: jnp.sum(p), lambda a, b: a + b,
                           manager=manager, prebind_wait_s=0.5)
        assert total == pytest.approx(ref, rel=1e-5)
        total = map_reduce(du, lambda p: jnp.sum(p), lambda a, b: a + b,
                           manager=manager, pipeline=False,
                           prebind_wait_s=0.5)
        assert total == pytest.approx(ref, rel=1e-5)
        assert seen and all(w == 0.5 for w in seen)

        # default stays None: each pilot's own configured bound applies
        seen.clear()
        map_reduce(du, lambda p: jnp.sum(p), lambda a, b: a + b,
                   manager=manager)
        assert seen and all(w is None for w in seen)
    finally:
        svc.cancel_all()


def test_cu_prebind_wait_s_overrides_pilot_default():
    """A CU-level prebind_wait_s bounds the stage-in wait even when the
    pilot's default is effectively unbounded: a CU carrying a
    never-resolving prebind future must start after ITS OWN bound."""
    from concurrent.futures import Future

    from repro.core.pilot import ComputeUnit, ComputeUnitDescription
    import time as _time

    svc = PilotComputeService()
    try:
        pilot = svc.submit_pilot(PilotComputeDescription(
            backend="inprocess", prebind_wait_s=300.0))
        cu = ComputeUnit(ComputeUnitDescription(
            fn=lambda: "ran", prebind_wait_s=0.2))
        cu.prebind_futures = [Future()]     # wedged stage-in, never lands
        t0 = _time.perf_counter()
        pilot.submit_cu(cu)
        assert cu.result(timeout=30) == "ran"
        assert _time.perf_counter() - t0 < 10.0     # 0.2s bound, not 300
    finally:
        svc.cancel_all()
