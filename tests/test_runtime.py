"""Runtime layer: stragglers, resilient runner, elastic mesh planning."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.core import (ComputeDataManager, ComputeUnitDescription,
                        PilotComputeDescription, PilotComputeService)
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import FaultPolicy, SimulatedClusterBackend
from repro.runtime.elastic import ElasticController, plan_mesh
from repro.runtime.fault_tolerance import ResilientRunner
from repro.runtime.stragglers import StragglerMonitor, run_speculative


@pytest.fixture
def service():
    svc = PilotComputeService()
    yield svc
    svc.cancel_all()


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold=3.0, min_samples=5)
    mon.durations.extend([0.1] * 10)

    class FakeCU:
        id = "slow"
        start_time = time.monotonic() - 5.0
        end_time = 0.0
    assert mon.is_straggling(FakeCU())
    assert "slow" in mon.flagged


def test_speculative_execution_backup_wins(service):
    register_backend(SimulatedClusterBackend(
        substrate="slurm",
        policy=FaultPolicy(straggle_cu_ids=frozenset({"lag"}),
                           straggle_seconds=2.0)))
    service.submit_pilot(PilotComputeDescription(backend="simulated"))
    service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    manager = ComputeDataManager(service)
    mon = StragglerMonitor(threshold=3.0, min_samples=3)
    mon.durations.extend([0.02] * 5)
    t0 = time.monotonic()
    out, info = run_speculative(
        manager, ComputeUnitDescription(fn=lambda: "done", name="lag"), mon)
    assert out == "done"
    assert info["launched"] >= 2          # a backup was launched
    assert time.monotonic() - t0 < 2.0         # didn't wait for the straggler


def test_resilient_runner_recovers_from_pilot_loss(service, tmp_path):
    register_backend(SimulatedClusterBackend(
        substrate="yarn", policy=FaultPolicy(fail_devices_at=4)))
    ckpt = CheckpointManager(tmp_path)
    runner = ResilientRunner(
        service, PilotComputeDescription(backend="simulated"),
        ckpt, checkpoint_every=2, max_recoveries=3)

    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {"x": state["x"]}

    state = {"x": jnp.float32(0)}
    final, metrics = runner.run(state, step_fn, num_steps=10,
                                batch_fn=lambda i: jnp.float32(1))
    assert float(final["x"]) == 10.0       # exactly-once effective progress
    assert len(runner.recoveries) >= 1     # recovery actually happened
    assert runner.recoveries[0].restored_step <= runner.recoveries[0].step


def test_plan_mesh_degrades_gracefully():
    p = plan_mesh(256, 16)
    assert p.shape == (16, 16) and p.dropped_devices == 0
    p = plan_mesh(255, 16)          # lost one chip
    assert p.dropped_devices < 16   # wastes at most a partial row
    assert (p.shape[0] * p.shape[1]) + p.dropped_devices == 255
    p = plan_mesh(7, 16)            # fewer survivors than model-parallel
    assert p.shape[1] <= 7


def test_elastic_controller_tracks_generations():
    ctl = ElasticController(model_parallel=1)
    devs = jax.devices()
    ctl.form(devs)
    ctl.on_failure(devs)  # same devices, new generation
    assert ctl.generation == 2
    assert len(ctl.events) == 2


def test_elastic_reshard_state_roundtrip():
    from repro.models.common import ParamSpec
    from repro.runtime.elastic import build_mesh, reshard_state
    from repro.parallel.sharding import AxisRules
    spec = {"w": ParamSpec((8, 16), ("embed", "mlp"))}
    host = {"w": np.arange(128, dtype=np.float32).reshape(8, 16)}
    plan = plan_mesh(jax.device_count(), 1)
    mesh = build_mesh(jax.devices(), plan)
    out = reshard_state(host, spec, mesh, AxisRules())
    np.testing.assert_array_equal(np.asarray(out["w"]), host["w"])
