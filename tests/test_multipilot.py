"""Multi-pilot distributed Pilot-Data: per-pilot TierManagers, the replica
registry (consistency under concurrent replicate/evict/delete), coherent
invalidation on writes/deletes, replica-aware scheduler placement,
pre-binding stage-in landing before CU start, and retry excluding the
pilot that just failed."""
import threading

import numpy as np
import pytest

from repro.core import (ComputeDataManager, ComputeUnitDescription, DataUnit,
                        PilotComputeDescription, PilotComputeService,
                        PilotDataService, TierManager, make_backend)
from repro.core.backends.base import register_backend
from repro.core.backends.simulated import FaultPolicy, SimulatedClusterBackend
from repro.core.mapreduce import _replica_groups

KB = 1024


@pytest.fixture
def service():
    svc = PilotComputeService()
    yield svc
    svc.cancel_all()


def _pilot(svc, pds, device_budget=None):
    """An inprocess pilot with its own private TierManager."""
    pilot = svc.submit_pilot(PilotComputeDescription(backend="inprocess"))
    pilot.attach_tier_manager(TierManager(
        {"host": make_backend("host"), "device": make_backend("device")},
        {"device": device_budget}, promote_threshold=0))
    pds.register_pilot(pilot)
    return pilot


def _home_du(name, parts=4, rows=64):
    arr = np.arange(parts * rows * 4, dtype=np.float32).reshape(-1, 4)
    return DataUnit.from_array(name, arr, parts,
                               {"host": make_backend("host")}, tier="host")


def test_replica_readable_from_both_pilots_with_coherent_delete(service):
    pds = PilotDataService()
    a, b = _pilot(service, pds), _pilot(service, pds)
    du = pds.register(_home_du("rep"))
    ref = np.asarray(du.partition(0)).copy()
    du.replicate_to_pilot(a, parts=[0])
    du.replicate_to_pilot(b, parts=[0])
    key = du._key(0)
    assert set(pds.holders(key)) == {a.id, b.id}
    # both pilots serve the partition from their OWN tiers
    np.testing.assert_array_equal(du.partition(0, pilot=a), ref)
    np.testing.assert_array_equal(du.partition(0, pilot=b), ref)
    assert a.tier_manager.tier_of(key) == "device"
    assert b.tier_manager.tier_of(key) == "device"
    # coherent delete: every replica AND the home copy are gone
    du.delete()
    assert pds.holders(key) == []
    assert a.tier_manager.tier_of(key) is None
    assert b.tier_manager.tier_of(key) is None
    with pytest.raises(KeyError):
        du.partition(0)
    pds.close()


def test_update_partition_invalidates_stale_replicas(service):
    pds = PilotDataService()
    a, b = _pilot(service, pds), _pilot(service, pds)
    du = pds.register(_home_du("wr"))
    du.replicate_to_pilot(a)
    du.replicate_to_pilot(b)
    fresh = np.full_like(np.asarray(du.partition(1)), 42.0)
    du.update_partition(1, fresh)
    # the write dropped both replicas; reads re-pull the new value
    assert pds.holders(du._key(1)) == []
    np.testing.assert_array_equal(du.partition(1, pilot=a), fresh)
    np.testing.assert_array_equal(du.partition(1, pilot=b), fresh)
    # the pull-through re-established pilot-a's replica
    assert pds.tier_on(du._key(1), a.id) is not None
    pds.close()


def test_pull_through_read_caches_in_pilot_and_respects_budget(service):
    pds = PilotDataService()
    du = pds.register(_home_du("pull", parts=4))
    part_bytes = du.nbytes() // 4
    # room for only two partitions on-device; overflow demotes to pilot host
    a = _pilot(service, pds, device_budget=2 * part_bytes + part_bytes // 2)
    for i in range(4):
        du.partition(i, pilot=a)
    res = du.replica_residency(a)
    assert sum(res.values()) == 4               # pilot holds every partition
    assert res.get("device", 0) == 2            # but only 2 fit its budget
    assert a.tier_manager.peak_usage("device") <= (
        2 * part_bytes + part_bytes // 2)
    pds.close()


def test_scheduler_places_cu_on_majority_replica_holder(service):
    pds = PilotDataService()
    a, b = _pilot(service, pds), _pilot(service, pds)
    du = pds.register(_home_du("sched", parts=4))
    du.replicate_to_pilot(a, parts=[3])
    du.replicate_to_pilot(b, parts=[0, 1, 2])
    manager = ComputeDataManager(service)
    desc = ComputeUnitDescription(fn=lambda: "done", input_data=(du,))
    assert manager.score(b, desc) > manager.score(a, desc)
    cu = manager.submit(desc)
    assert cu.result(30) == "done"
    assert manager.history[-1]["pilot"] == b.id
    pds.close()


def test_replica_groups_sticky_and_balanced(service):
    pds = PilotDataService()
    a, b = _pilot(service, pds), _pilot(service, pds)
    du = pds.register(_home_du("grp", parts=6))
    du.replicate_to_pilot(b, parts=[0, 4])
    manager = ComputeDataManager(service)
    groups = dict((p.id, idxs) for p, idxs in _replica_groups(du, manager))
    # held partitions stick to their holder; the rest balance the load
    assert set(groups[b.id]) >= {0, 4}
    assert len(groups[a.id]) == 3 and len(groups[b.id]) == 3
    assert sorted(groups[a.id] + groups[b.id]) == list(range(6))
    pds.close()


def test_prebinding_stage_in_lands_before_cu_start(service):
    pds = PilotDataService()
    a = _pilot(service, pds)
    du = pds.register(_home_du("bind", parts=4))
    manager = ComputeDataManager(service)

    def probe():
        # runs INSIDE the CU: the declared first partitions must already
        # be resident in the executing pilot when the body starts
        return (pds.tier_on(du._key(0), a.id) is not None
                and pds.tier_on(du._key(1), a.id) is not None)

    cu = manager.submit(ComputeUnitDescription(
        fn=probe, input_data=(du,), prefetch_parts=(0, 1)))
    assert cu.prebind_futures           # stage-in was queued at bind time
    assert cu.result(30) is True
    pds.close()


def test_result_with_retry_excludes_failed_pilot(service):
    register_backend(SimulatedClusterBackend(
        substrate="slurm", policy=FaultPolicy(fail_cu_ids=frozenset({"job"}))))
    flaky = service.submit_pilot(PilotComputeDescription(
        backend="simulated", affinity="fast"))
    backup = service.submit_pilot(PilotComputeDescription(
        backend="inprocess"))
    manager = ComputeDataManager(service)
    # the affinity bonus makes the flaky pilot the scheduler's first choice,
    # so only the failure-exclusion can move the retry off it
    desc = ComputeUnitDescription(fn=lambda: "ok", name="job",
                                  affinity="fast")
    assert manager.result_with_retry(desc, retries=2) == "ok"
    pilots = [h["pilot"] for h in manager.history[-2:]]
    assert pilots == [flaky.id, backup.id]


def test_single_pilot_retry_resets_exclusion(service):
    """When every healthy pilot has failed the CU, exclusion resets instead
    of stranding the retry in the late-binding queue."""
    register_backend(SimulatedClusterBackend(
        substrate="slurm",
        policy=FaultPolicy(fail_cu_ids=frozenset({"solo"}))))
    service.submit_pilot(PilotComputeDescription(backend="simulated"))
    manager = ComputeDataManager(service)
    desc = ComputeUnitDescription(fn=lambda: "ok", name="solo")
    assert manager.result_with_retry(desc, retries=2) == "ok"


def test_simulated_backend_provisions_per_pilot_tier_manager(service):
    register_backend(SimulatedClusterBackend(substrate="spark"))
    pilot = service.submit_pilot(PilotComputeDescription(
        backend="simulated", memory_gb=0.125, host_memory_gb=0.25))
    assert pilot.tier_manager is not None
    assert pilot.tier_manager.budget("device") == int(0.125 * 2 ** 30)
    assert pilot.tier_manager.budget("host") == int(0.25 * 2 ** 30)


def test_replica_registry_consistent_under_concurrent_churn(service):
    """Replicate / evict (budget pressure) / write-invalidate hammering:
    the registry never desynchronizes from the per-pilot managers."""
    pds = PilotDataService()
    du = pds.register(_home_du("churn", parts=8))
    part_bytes = du.nbytes() // 8
    pilots = [_pilot(service, pds,
                     device_budget=3 * part_bytes + part_bytes // 2)
              for _ in range(2)]
    stop = threading.Event()
    errors = []

    def run(fn):
        try:
            i = 0
            while not stop.is_set():
                fn(i)
                i += 1
        except Exception as e:  # noqa: BLE001
            errors.append(e)
            stop.set()

    def replicator(pilot):
        def go(i):
            du.partition(i % 8, pilot=pilot)   # pull-through replicate
        return go

    def pressurer(pilot):
        def go(i):
            # unrelated keys churn the pilot's device budget -> demotions
            pilot.tier_manager.put(f"fill-{pilot.id}-{i % 4}",
                                   np.zeros(part_bytes // 4, np.float32),
                                   "device")
        return go

    def writer(i):
        du.update_partition(i % 8, np.full((16, 4), float(i), np.float32))

    workers = [replicator(pilots[0]), replicator(pilots[1]),
               pressurer(pilots[0]), pressurer(pilots[1]), writer]
    threads = [threading.Thread(target=run, args=(w,)) for w in workers]
    for t in threads:
        t.start()
    stop.wait(1.5)
    stop.set()
    for t in threads:
        t.join(20)
    if errors:
        raise errors[0]
    pds.drain(timeout=30)
    # invariant: the registry agrees exactly with per-pilot residency
    for i in range(8):
        key = du._key(i)
        holding = {p.id for p in pilots
                   if p.tier_manager.tier_of(key) is not None}
        assert set(pds.holders(key)) == holding
    # and every partition still reads coherently through every pilot
    for i in range(8):
        home = np.asarray(du.partition(i))
        for p in pilots:
            np.testing.assert_array_equal(du.partition(i, pilot=p), home)
    pds.close()
