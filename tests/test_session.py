"""Pilot-API v2: the PilotSession façade (lifecycle + teardown), the
composed resource descriptions (validation + flat-legacy compat), the
legacy-vs-session parity suite, the bounded scheduler history, and the
configurable pre-binding wait bound."""
import time
from concurrent.futures import Future
from pathlib import Path

import numpy as np
import pytest

from repro.core import (ComputeDataManager, ComputeUnit,
                        ComputeUnitDescription, DataUnit,
                        DurabilityDescription, MemoryDescription,
                        PilotComputeDescription, PilotComputeService,
                        PilotDataService, PilotSession, State, kmeans,
                        make_backend, make_blobs, map_reduce)

import jax.numpy as jnp


# -- composed resource descriptions -------------------------------------
def test_description_flat_and_nested_spellings_are_equal():
    flat = PilotComputeDescription(
        backend="inprocess", memory_gb=0.125, host_memory_gb=0.25,
        eviction_policy="gdsf", hysteresis=2, stager_workers=3,
        checkpoint_dir="/tmp/ck", checkpoint_gb=1.0)
    nested = PilotComputeDescription(
        backend="inprocess",
        memory=MemoryDescription(memory_gb=0.125, host_memory_gb=0.25,
                                 eviction_policy="gdsf", hysteresis=2,
                                 stager_workers=3),
        durability=DurabilityDescription(checkpoint_dir="/tmp/ck",
                                         checkpoint_gb=1.0))
    assert flat == nested
    # flat read access keeps working through the compat properties
    assert nested.memory_gb == 0.125
    assert nested.host_memory_gb == 0.25
    assert nested.eviction_policy == "gdsf"
    assert nested.checkpoint_dir == "/tmp/ck"
    assert nested.checkpoint_gb == 1.0


@pytest.mark.parametrize("bad_kwargs, exc", [
    (dict(memory_gb=-0.5), ValueError),
    (dict(host_memory_gb=-1), ValueError),
    (dict(eviction_policy="fifo"), ValueError),
    (dict(hysteresis=-1), ValueError),
    (dict(stager_workers=0), ValueError),
    (dict(checkpoint_gb=1.0), ValueError),          # budget without a dir
    (dict(num_devices=0), ValueError),
    (dict(queue_depth=0), ValueError),
    (dict(prebind_wait_s=0.0), ValueError),
    (dict(totally_bogus=1), TypeError),             # unknown field
    (dict(memory=MemoryDescription(), memory_gb=1.0), ValueError),  # both
    (dict(durability=DurabilityDescription(),
          checkpoint_dir="/x"), ValueError),
])
def test_description_validation_rejects_bad_asks(bad_kwargs, exc):
    with pytest.raises(exc):
        PilotComputeDescription(**bad_kwargs)


# -- session lifecycle ---------------------------------------------------
def test_session_teardown_is_deterministic_and_idempotent():
    with PilotSession() as s:
        pilots = s.add_pilots(2, memory_gb=0.02)
        du = s.data("x", np.ones((64, 4), np.float32), parts=2)
        assert s.map_reduce(du, lambda p: jnp.sum(p),
                            lambda a, b: a + b) == 64 * 4
    assert s.closed
    # pilots released: service emptied, workers stopped, managers closed
    assert s.compute.pilots == {}
    for p in pilots:
        assert p.state in (State.DONE, State.CANCELED)
        assert p.tier_manager._closed
    # data service shut down (replicator pool refuses new work)
    assert s.data_service.replicate_async(du, 0, pilots[0].id).result() is None
    # closed sessions refuse new pilots/data, and close() is idempotent
    with pytest.raises(RuntimeError):
        s.add_pilot(memory_gb=0.01)
    with pytest.raises(RuntimeError):
        s.data("y", np.ones(4), parts=1)
    s.close()


def test_session_data_names_are_unique_and_tiers_checked():
    with PilotSession() as s:
        s.data("dup", np.ones((8, 2), np.float32), parts=2)
        with pytest.raises(ValueError):
            s.data("dup", np.zeros((8, 2), np.float32), parts=2)
        with pytest.raises(ValueError):
            s.data("odd", np.ones(4), parts=1, tier="warp")
        assert s.get_data("dup").num_partitions == 2


def test_session_add_pilot_rejects_desc_plus_kwargs():
    with PilotSession() as s:
        with pytest.raises(TypeError):
            s.add_pilot(PilotComputeDescription(), memory_gb=0.5)


def test_session_file_tier_home_in_scratch_dir():
    with PilotSession() as s:
        du = s.data("filed", np.arange(32, dtype=np.float32).reshape(-1, 4),
                    parts=2, tier="file")
        assert du.tier == "file"
        np.testing.assert_array_equal(
            np.asarray(du.partition(0)).ravel(), np.arange(16))
        scratch = s._scratch
        assert scratch is not None and Path(scratch).exists()
    # teardown removes the session-owned scratch dir (no /tmp leak)
    assert not Path(scratch).exists()


# -- legacy-vs-session parity -------------------------------------------
def _legacy_multipilot_kmeans(pts, parts, k, iters):
    svc = PilotComputeService()
    pds = PilotDataService()
    manager = ComputeDataManager(svc)
    try:
        pilots = [svc.submit_pilot(PilotComputeDescription(
            backend="inprocess", memory_gb=0.05)) for _ in range(2)]
        for p in pilots:
            pds.register_pilot(p)
        du = pds.register(DataUnit.from_array(
            "pts", pts, parts, {"host": make_backend("host")}, tier="host"))
        du.replicate_to_pilot(pilots[0], parts=range(0, parts // 2))
        du.replicate_to_pilot(pilots[1], parts=range(parts // 2, parts))
        res = kmeans(du, k=k, iters=iters, manager=manager)
        residency = [du.replica_residency(p) for p in pilots]
        return res, residency
    finally:
        pds.close()
        svc.cancel_all()


def test_session_api_parity_with_legacy_surface():
    """The acceptance bar: the same multi-pilot KMeans through both
    surfaces gives the same numbers and the same per-pilot residency —
    the façade changes ergonomics, not semantics."""
    pts, _ = make_blobs(4_000, 8, d=8, seed=0)
    parts, k, iters = 8, 8, 3
    legacy, legacy_res = _legacy_multipilot_kmeans(pts, parts, k, iters)

    with PilotSession() as s:
        pilots = s.add_pilots(2, memory_gb=0.05)
        du = s.data("pts", pts, parts=parts)
        du.replicate_to_pilot(pilots[0], parts=range(0, parts // 2))
        du.replicate_to_pilot(pilots[1], parts=range(parts // 2, parts))
        v2 = s.kmeans(du, k=k, iters=iters)
        v2_res = [du.replica_residency(p) for p in pilots]
        # both pilots actually served CUs through the façade
        assert len(s.manager.stats()["per_pilot"]) == 2

    np.testing.assert_allclose(v2.centroids, legacy.centroids)
    assert v2.sse_history == pytest.approx(legacy.sse_history)
    assert v2_res == legacy_res


def test_module_map_reduce_accepts_session_as_manager():
    pts = np.ones((256, 4), np.float32)
    with PilotSession() as s:
        s.add_pilot(memory_gb=0.02)
        du = s.data("mr", pts, parts=4)
        via_session = s.map_reduce(du, lambda p: jnp.sum(p),
                                   lambda a, b: a + b)
        via_module = map_reduce(du, lambda p: jnp.sum(p),
                                lambda a, b: a + b, manager=s)
        assert float(via_session) == float(via_module) == 256 * 4
        with pytest.raises(TypeError):
            map_reduce(du, lambda p: p, lambda a, b: a + b,
                       manager="not-a-manager")


# -- bounded history + stats (satellite) ---------------------------------
def test_manager_history_is_bounded_and_stats_exact():
    svc = PilotComputeService()
    try:
        svc.submit_pilot(PilotComputeDescription(backend="inprocess"))
        manager = ComputeDataManager(svc, history_limit=5)
        cus = [manager.submit(ComputeUnitDescription(fn=lambda: None))
               for _ in range(12)]
        for cu in cus:
            cu.wait(30)
        assert len(manager.history) == 5            # window stays bounded
        st = manager.stats()
        assert st["submitted"] == 12                # lifetime stays exact
        assert sum(st["per_pilot"].values()) == 12
        assert st["history_limit"] == 5 and st["history_len"] == 5
        # the window keeps the MOST RECENT decisions
        assert [h["cu"] for h in manager.history] == [cu.id
                                                      for cu in cus[-5:]]
    finally:
        svc.cancel_all()


# -- configurable pre-binding wait bound (satellite) ---------------------
def test_short_prebind_bound_lets_cu_proceed_past_stuck_stage_in():
    svc = PilotComputeService()
    try:
        pilot = svc.submit_pilot(PilotComputeDescription(
            backend="inprocess", prebind_wait_s=0.2))
        assert pilot.desc.prebind_wait_s == 0.2
        cu = ComputeUnit(ComputeUnitDescription(fn=lambda: "ran"))
        cu.prebind_futures = [Future()]     # a stage-in that never lands
        t0 = time.time()
        pilot.submit_cu(cu)
        assert cu.result(10) == "ran"
        waited = time.time() - t0
        assert 0.15 <= waited < 5.0         # bounded by the ask, not 120s
    finally:
        svc.cancel_all()


def test_session_prebind_default_stamped_on_kwarg_pilots():
    with PilotSession(prebind_wait_s=0.5) as s:
        p = s.add_pilot(memory_gb=0.01)
        assert p.desc.prebind_wait_s == 0.5
        # an explicit description always wins over the session default
        q = s.add_pilot(PilotComputeDescription(memory_gb=0.01))
        assert q.desc.prebind_wait_s == 120.0
