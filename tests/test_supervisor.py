"""Self-healing sessions (PR 7): failure detection, quarantine, respawn,
replication repair, and the recovery races.

The contracts under test:

  * a stalled pilot is quarantined BEFORE any new task is scheduled onto
    it, and the quarantine filter fails closed (all-quarantined => late
    binding waits, never falls back onto a suspect);
  * a killed pilot is respawned from its own PilotComputeDescription and
    rejoins the data service + scheduler; the corpse leaves both;
  * replication-factor repair restores the declared target from
    surviving replicas or the checkpoint home, and never reads from a
    quarantined pilot (property-tested over random quarantine sets);
  * the recovery races: lose_volatile concurrent with a checkpoint
    flush, and session.close() during an in-flight respawn.
"""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Backoff, ComputeDataManager, ComputeUnitDescription,
                        DataUnit, FailureDetector, PilotComputeDescription,
                        PilotComputeService, PilotDataService, PilotSession,
                        PilotSupervisor, TierManager, make_backend)
from repro.core.backends.base import get_backend, register_backend
from repro.core.backends.simulated import (ChaosEvent, ChaosPolicy,
                                           SimulatedClusterBackend)
from repro.core.pilot import State


@pytest.fixture
def service():
    svc = PilotComputeService()
    yield svc
    svc.cancel_all()


def _attach_tm(pilot):
    pilot.attach_tier_manager(TierManager(
        {"host": make_backend("host"), "device": make_backend("device")},
        {}, promote_threshold=0))
    return pilot


def _chaos_backend(events, target_index=0, lose_memory=True):
    """Register a fresh simulated backend carrying a chaos schedule for
    its target_index-th provisioned pilot."""
    be = SimulatedClusterBackend(
        substrate="slurm",
        policy=ChaosPolicy(lose_memory=lose_memory, events=tuple(events),
                           target_index=target_index))
    register_backend(be)
    return be


# -- unit: backoff + detector -----------------------------------------------
def test_backoff_grows_is_capped_and_jittered():
    b = Backoff(base_s=0.01, cap_s=0.08, factor=2.0, jitter=0.5)
    for attempt in range(10):
        raw = min(0.08, 0.01 * 2 ** attempt)
        for _ in range(20):
            d = b.delay(attempt)
            assert raw * 0.5 - 1e-12 <= d <= raw + 1e-12
    # jitter actually spreads (not a constant)
    assert len({round(b.delay(3), 6) for _ in range(50)}) > 1
    # no-jitter backoff is deterministic
    nb = Backoff(base_s=0.01, cap_s=0.08, jitter=0.0)
    assert nb.delay(0) == 0.01 and nb.delay(2) == 0.04 and nb.delay(9) == 0.08


def test_failure_detector_phi_rises_with_silence():
    det = FailureDetector(min_interval_s=0.1)
    # regular beats at 0.1s intervals
    for k in range(5):
        det.observe("p", last_beat=k * 0.1, now=k * 0.1)
    assert det.phi("p", now=0.45) <= 1.0     # half an interval late: calm
    assert det.phi("p", now=0.8) >= 3.0      # 4 intervals of silence
    assert det.phi("p", now=4.0) >= 30.0     # definitely dead
    det.forget("p")
    assert det.phi("p", now=5.0) == 0.0      # unknown pilot: no suspicion


def test_health_surface_both_backends(service):
    _chaos_backend([])
    for backend in ("inprocess", "simulated"):
        p = service.submit_pilot(PilotComputeDescription(
            backend=backend, startup_seconds=0.01))
        h = get_backend(backend).health(p)
        assert h["alive"] and h["state"] == "Running"
        assert h["pilot"] == p.id and not h["busy"]
        age0 = h["heartbeat_age_s"]
        time.sleep(0.12)    # the idle worker loop keeps beating
        h2 = get_backend(backend).health(p)
        assert h2["heartbeat_age_s"] < 0.12 or h2["heartbeat_age_s"] >= age0


# -- satellite: event-driven wait_idle --------------------------------------
def test_wait_idle_wakes_on_completion_not_poll_tick(service):
    p = service.submit_pilot(PilotComputeDescription(backend="inprocess"))
    manager = ComputeDataManager(service)
    cu = manager.submit(ComputeUnitDescription(
        fn=lambda: time.sleep(0.15) or 41))
    t0 = time.monotonic()
    assert p.wait_idle(timeout=5.0)
    waited = time.monotonic() - t0
    assert cu.result() == 41
    assert waited < 2.0                      # woke with the CU, not at 5s
    # already idle: returns immediately
    t0 = time.monotonic()
    assert p.wait_idle(timeout=5.0)
    assert time.monotonic() - t0 < 0.05
    # a busy pilot times out honestly
    manager.submit(ComputeUnitDescription(fn=lambda: time.sleep(0.5)))
    assert not p.wait_idle(timeout=0.05)
    assert p.wait_idle(timeout=5.0)


# -- quarantine: before any task routes to the suspect ----------------------
def test_stalled_pilot_quarantined_before_any_new_task_schedules(service):
    """The acceptance assertion: the detector quarantines a stalled pilot
    while it still LOOKS alive (state Running), and from that point no
    new task is scheduled onto it."""
    _chaos_backend([ChaosEvent(at_s=0.15, action="stall", duration_s=2.0)])
    victim = _attach_tm(service.submit_pilot(PilotComputeDescription(
        backend="simulated", startup_seconds=0.01)))
    survivor = _attach_tm(service.submit_pilot(PilotComputeDescription(
        backend="inprocess")))
    manager = ComputeDataManager(service)
    sup = PilotSupervisor(compute=service, manager=manager,
                          interval_s=0.02, min_heartbeat_s=0.05,
                          suspect_phi=3.0, dead_phi=1e9,
                          auto_respawn=False).start()
    try:
        deadline = time.monotonic() + 5.0
        while victim.id not in sup.quarantined:
            assert time.monotonic() < deadline, "stall never suspected"
            time.sleep(0.01)
        # quarantined while the substrate still reports it Running — the
        # detector beat the state machine (grey failure caught early)
        assert victim.state == State.RUNNING
        assert victim.id in manager.policy.quarantined
        # no new task lands on the suspect
        for _ in range(16):
            cu = manager.submit(ComputeUnitDescription(fn=lambda: 1))
            assert cu.pilot_id == survivor.id
            assert cu.result(timeout=10) == 1
        batch = manager.submit_tasks([lambda: 2] * 32)
        assert batch.results(timeout=10) == [2] * 32
        assert all(t.pilot_id == survivor.id for t in batch)
    finally:
        sup.close()


def test_quarantine_fails_closed_then_readmits(service):
    p = _attach_tm(service.submit_pilot(PilotComputeDescription(
        backend="inprocess")))
    manager = ComputeDataManager(service)
    manager.policy.quarantine(p.id)
    assert manager.eligible_pilots() == []
    with pytest.raises(TimeoutError):
        # the whole fleet is suspect: late binding WAITS (and here times
        # out) instead of scheduling onto the suspect
        manager.select_pilot(ComputeUnitDescription(fn=lambda: 0),
                             timeout=0.2)
    manager.policy.readmit(p.id)
    assert manager.select_pilot(
        ComputeUnitDescription(fn=lambda: 0), timeout=1.0) is p


# -- respawn ----------------------------------------------------------------
def test_kill_respawns_pilot_from_its_own_description():
    _chaos_backend([ChaosEvent(at_s=0.2, action="kill")])
    s = PilotSession(supervise=True,
                     supervisor_kwargs={"interval_s": 0.02,
                                        "min_heartbeat_s": 0.05})
    try:
        victim = s.add_pilot(backend="simulated", startup_seconds=0.01,
                             memory_gb=0.01, host_memory_gb=0.03,
                             affinity="rack0")
        s.add_pilot(memory_gb=0.01)
        deadline = time.monotonic() + 8.0
        while not s.supervisor.respawns:
            assert time.monotonic() < deadline, "kill never respawned"
            time.sleep(0.02)
        ev = s.supervisor.respawns[0]
        assert ev.old_pilot == victim.id and ev.new_pilot
        new = s.compute.pilots[ev.new_pilot]
        # replacement provisioned from the dead pilot's own description
        assert new.desc is victim.desc
        assert new.desc.affinity == "rack0"
        assert new.state == State.RUNNING
        # corpse left the fleet and the data service; replacement joined
        assert victim.id not in s.compute.pilots
        assert not s.data_service.knows(victim.id)
        assert s.data_service.knows(new.id)
        # quarantine registry is clean again (dead id readmitted)
        assert victim.id not in s.supervisor.quarantined
        # and the fleet still does work
        assert s.run(lambda: 7).result(timeout=10) == 7
    finally:
        s.close()


def test_deliberate_release_is_not_mistaken_for_death():
    _chaos_backend([])
    s = PilotSession(supervise=True,
                     supervisor_kwargs={"interval_s": 0.02,
                                        "min_heartbeat_s": 0.05})
    try:
        a = s.add_pilot(memory_gb=0.01)
        s.add_pilot(memory_gb=0.01)
        s.release(a)
        time.sleep(0.3)     # give the monitor time to misfire (it must not)
        assert not s.supervisor.respawns
        assert len(s.pilots) == 1
    finally:
        s.close()


# -- replication repair -----------------------------------------------------
def test_repair_restores_replication_target_after_pilot_loss():
    _chaos_backend([ChaosEvent(at_s=0.3, action="kill")])
    s = PilotSession(supervise=True,
                     supervisor_kwargs={"interval_s": 0.02,
                                        "min_heartbeat_s": 0.05,
                                        "repair_interval_s": 0.03})
    try:
        victim = s.add_pilot(backend="simulated", startup_seconds=0.01,
                             memory_gb=0.01, host_memory_gb=0.05)
        s.add_pilots(2, memory_gb=0.01, host_memory_gb=0.05)
        rng = np.random.default_rng(3)
        arr = rng.normal(size=(48, 4)).astype(np.float32)
        du = s.data("pts", arr, parts=6, replication=2)
        s.data_service.replicate_to_pilot(du, victim.id, tier="host")
        deadline = time.monotonic() + 10.0
        while True:
            rs = s.data_service.replication_stats()["pts"]
            if (s.supervisor.respawns and rs["under"] == 0
                    and all(c >= 2 for c in rs["per_partition"].values())):
                break
            assert time.monotonic() < deadline, f"repair incomplete: {rs}"
            time.sleep(0.05)
        assert s.data_service.counters["repairs"] > 0
        # zero data loss: every partition byte-identical to the source
        parts = np.array_split(arr, 6, axis=0)
        for i in range(6):
            np.testing.assert_array_equal(np.asarray(du.partition(i)),
                                          parts[i])
        st_ = s.stats()["supervisor"]
        assert st_["repair_queue_depth"] == 0
        assert st_["replication"]["pts"]["under"] == 0
    finally:
        s.close()


@settings(max_examples=6)
@given(quarantined=st.lists(st.integers(0, 2), min_size=0, max_size=3),
       wipe=st.integers(0, 2))
def test_repair_never_reads_from_quarantined_pilot(quarantined, wipe):
    """Property: whatever subset of the fleet is quarantined and whoever
    lost its volatile tiers, replication repair only ever reads from
    non-quarantined managers (the checkpoint home is the fallback)."""
    import tempfile
    svc = PilotComputeService()
    try:
        pilots = [_attach_tm(svc.submit_pilot(PilotComputeDescription(
            backend="inprocess"))) for _ in range(3)]
        with tempfile.TemporaryDirectory() as tmp:
            pds = PilotDataService(checkpoint_dir=tmp + "/ck")
            try:
                for p in pilots:
                    pds.register_pilot(p)
                arr = np.arange(64, dtype=np.float32).reshape(16, 4)
                du = DataUnit.from_array("prop", arr, 4,
                                         {"host": make_backend("host")},
                                         tier="host")
                pds.register(du, persist=True, replication=2)
                pds.flush_checkpoints()
                # seed replicas everywhere, then record every manager read
                for p in pilots:
                    pds.replicate_to_pilot(du, p.id, tier="host")
                reads = []
                for p in pilots:
                    tm, pid = p.tier_manager, p.id
                    orig = tm.get
                    tm.get = (lambda key, _o=orig, _pid=pid:
                              (reads.append(_pid), _o(key))[1])
                pilots[wipe].tier_manager.lose_volatile()
                for qi in set(quarantined):
                    pds.avoid_pilot(pilots[qi].id)
                reads.clear()
                pds.repair_once()
                bad = {pilots[qi].id for qi in set(quarantined)}
                assert not (set(reads) & bad), (
                    f"repair read from quarantined {set(reads) & bad}")
                # repaired copies are byte-identical to the source
                parts = np.array_split(arr, 4, axis=0)
                for i in range(4):
                    for pid in pds.live_holders(du._key(i)):
                        tm = pds.manager_for(pid)
                        if tm.tier_of(du._key(i)) is not None:
                            np.testing.assert_array_equal(
                                np.asarray(tm.get(du._key(i))), parts[i])
            finally:
                pds.close()     # before the checkpoint root is removed
    finally:
        svc.cancel_all()


# -- recovery races ---------------------------------------------------------
def test_lose_volatile_concurrent_with_checkpoint_flush(tmp_path, service):
    """Node death racing a checkpoint flush must leave every partition
    recoverable: either the flush won (checkpoint serves it) or the home
    placement still has it — never an error, never wrong bytes."""
    pds = PilotDataService(checkpoint_dir=str(tmp_path / "ck"))
    a = _attach_tm(service.submit_pilot(PilotComputeDescription(
        backend="inprocess")))
    b = _attach_tm(service.submit_pilot(PilotComputeDescription(
        backend="inprocess")))
    pds.register_pilot(a)
    pds.register_pilot(b)
    rng = np.random.default_rng(11)
    arr = rng.normal(size=(64, 4)).astype(np.float32)
    du = DataUnit.from_array("race", arr, 8,
                             {"host": make_backend("host")}, tier="host")
    pds.register(du)
    pds.replicate_to_pilot(du, a.id, tier="host")
    errors = []

    def _flush_loop():
        try:
            for _ in range(10):
                pds.persist(du)
                pds.flush_checkpoints()
        except Exception as e:      # noqa: BLE001
            errors.append(e)

    t = threading.Thread(target=_flush_loop)
    t.start()
    time.sleep(0.005)
    a.tier_manager.lose_volatile()          # node death mid-flush
    t.join(30)
    assert not errors, errors
    parts = np.array_split(arr, 8, axis=0)
    for i in range(8):
        np.testing.assert_array_equal(
            np.asarray(pds.read(du, i, b.id, pull_tier="host")), parts[i])
    pds.close()


def test_session_close_during_inflight_respawn():
    """session.close() racing an in-flight respawn must neither deadlock
    nor leak a pilot: the supervisor joins first, an aborted respawn is
    recorded with an empty new_pilot, and the fleet is fully released."""
    # slow re-provision (startup_seconds) makes the respawn window wide
    _chaos_backend([ChaosEvent(at_s=0.1, action="kill")])
    s = PilotSession(supervise=True,
                     supervisor_kwargs={"interval_s": 0.02,
                                        "min_heartbeat_s": 0.05})
    victim = s.add_pilot(backend="simulated", startup_seconds=0.4,
                         memory_gb=0.01)
    deadline = time.monotonic() + 5.0
    while victim.state == State.RUNNING:    # wait for the kill to land
        assert time.monotonic() < deadline
        time.sleep(0.01)
    time.sleep(0.05)                        # let the monitor start respawn
    t0 = time.monotonic()
    s.close()                               # races the in-flight respawn
    assert time.monotonic() - t0 < 10.0
    assert s.closed
    assert len(s.pilots) == 0               # nothing leaked past close
    # whichever way the race went, the record is consistent: an aborted
    # respawn has new_pilot == "", a completed one was released by close
    for ev in s.supervisor.respawns:
        assert ev.old_pilot == victim.id
    s.close()                               # idempotent


# -- observability ----------------------------------------------------------
def test_session_stats_surface_supervisor_observability():
    _chaos_backend([])
    s = PilotSession(supervise=True,
                     supervisor_kwargs={"interval_s": 0.02})
    try:
        p = s.add_pilot(memory_gb=0.01)
        du = s.data("obs", np.ones((8, 2), np.float32), parts=2,
                    replication=1)
        time.sleep(0.15)
        st_ = s.stats()
        sup = st_["supervisor"]
        assert p.id in sup["pilots"]
        row = sup["pilots"][p.id]
        assert {"state", "heartbeat_age_s", "phi", "quarantined"} <= set(row)
        assert row["state"] == "Running" and not row["quarantined"]
        assert sup["quarantined"] == [] and sup["respawns"] == []
        assert "repair_queue_depth" in sup
        assert sup["replication"]["obs"]["target"] == 1
        assert set(sup["replication"]["obs"]["per_partition"]) == {0, 1}
    finally:
        s.close()
